import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# Never set this in conftest/pyproject — smoke tests and benches see the
# single real CPU device; only the dry-run forges the production topology.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the step function is jit'd with the production shardings
and lowered against ShapeDtypeStruct stand-ins (no allocation), then
compiled.  Success proves the distribution config is coherent: shardings
divide, collectives legal, memory bounded.  Outputs per cell:

  * compiled.memory_analysis()  — per-device bytes (fits 16 GiB HBM?)
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline
  * collective bytes parsed from the optimized HLO (launch/roofline.py)

CLI:
    python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    python -m repro.launch.dryrun --arch catapultdb --shape search
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/]
  --all iterates every assigned cell in a subprocess per cell (isolates
  failures, bounds compile-cache memory).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.compat import mesh_context
from repro.launch import roofline as rl
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import model as M
from repro.models.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.optim import adamw

GiB = 2 ** 30
HBM_PER_CHIP = 16 * GiB    # TPU v5e


def opt_config(cfg) -> adamw.AdamWConfig:
    """arctic-480b: bf16 moments — f32 AdamW moments alone are 15 GiB/chip
    on a single pod (DESIGN.md §5 / EXPERIMENTS.md §Dry-run)."""
    if cfg.name == "arctic-480b":
        return adamw.AdamWConfig(moment_dtype="bfloat16")
    return adamw.AdamWConfig()


def _extend_fsdp(pspecs, mesh):
    """FSDP axes in param specs are written as the TUPLE ("data",); on the
    multi-pod mesh they widen to ("data", "pod") so arctic-scale expert
    weights shard over every data-parallel chip."""
    if "pod" not in mesh.axis_names:
        return pspecs

    def one(spec):
        if spec is None:
            return spec
        out = tuple(("data", "pod") if isinstance(e, tuple) and e == ("data",)
                    else e for e in spec)
        return jax.sharding.PartitionSpec(*out)

    return jax.tree_util.tree_map(one, pspecs,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.sharding.PartitionSpec))


def input_specs(cfg, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins + shardings for one cell.

    Returns (fn, args_sds tuple, in_shardings tuple, donate_argnums,
    model_flops).
    """
    seq_len, global_batch, kind = SHAPES[shape_name]
    ba = batch_axes(mesh)
    model_size = mesh.shape["model"]
    ns = lambda spec: NamedSharding(mesh, spec)
    shard_tree = lambda pspecs: jax.tree_util.tree_map(ns, pspecs)

    param_sds = M.specs(cfg)
    param_pspecs = _extend_fsdp(M.pspecs(cfg), mesh)
    param_sh = shard_tree(param_pspecs)

    bspec = P(ba) if global_batch > 1 else P()
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)

    def batch_specs(b, s):
        sds = {"tokens": tok(b, s)}
        sh = {"tokens": ns(bspec)}
        if cfg.family == "vlm":
            sds["tokens"] = tok(b, s - cfg.n_frontend_tokens)
            sds["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
            sh["patches"] = ns(bspec)
        if cfg.family == "encdec":
            sds["frames"] = jax.ShapeDtypeStruct(
                (b, s, cfg.frontend_dim), jnp.float32)
            sh["frames"] = ns(bspec)
        return sds, sh

    mf = rl.model_flops(cfg, kind, seq_len, global_batch)
    hbm = rl.analytic_hbm_bytes(cfg, kind, seq_len, global_batch)

    if kind == "train":
        ocfg = opt_config(cfg)
        fn = make_train_step(cfg, ocfg)
        mdt = jnp.dtype(ocfg.moment_dtype)
        mom_sds = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, mdt), param_sds)
        opt_pspecs = adamw.zero1_pspecs(param_sds, param_pspecs,
                                        data_size=mesh.shape["data"])
        opt_sds = adamw.AdamWState(mu=mom_sds, nu=mom_sds,
                                   step=jax.ShapeDtypeStruct((), jnp.int32))
        opt_sh = adamw.AdamWState(mu=shard_tree(opt_pspecs),
                                  nu=shard_tree(opt_pspecs), step=ns(P()))
        bsds, bsh = batch_specs(global_batch, seq_len)
        return (fn, (param_sds, opt_sds, bsds), (param_sh, opt_sh, bsh),
                (0, 1), mf, hbm)

    cache_sds = M.cache_specs(cfg, global_batch, seq_len, ba, model_size)
    cache_sh = shard_tree(M.cache_pspecs(cfg, global_batch, seq_len, ba,
                                         model_size))
    if kind == "prefill":
        fn = make_prefill_step(cfg)
        bsds, bsh = batch_specs(global_batch, seq_len)
        return (fn, (param_sds, bsds, cache_sds),
                (param_sh, bsh, cache_sh), (2,), mf, hbm)

    # decode: one new token against a seq_len cache
    fn = make_decode_step(cfg)
    tsds = tok(global_batch, 1)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (fn, (param_sds, tsds, cache_sds, pos),
            (param_sh, ns(bspec), cache_sh, ns(P())), (2,), mf, hbm)


def catapultdb_specs(mesh):
    """The paper's own cell: one sharded catapulted search step."""
    from repro.configs.catapultdb import CONFIG as E
    from repro.core.beam_search import SearchSpec
    from repro.core.sharded import engine_state_specs, make_sharded_search

    sds, pspecs = engine_state_specs(mesh, E.n_vectors, E.dim, E.max_degree,
                                     E.lsh_bits, E.bucket_capacity)
    spec = SearchSpec(beam_width=E.beam_width, k=E.k, max_iters=E.max_iters)
    step = make_sharded_search(mesh, spec, E.n_vectors, E.lsh_bits)
    ns = lambda s: NamedSharding(mesh, s)
    qaxes = batch_axes(mesh)
    q_sds = jax.ShapeDtypeStruct((E.query_batch, E.dim), jnp.float32)
    state_sh = jax.tree_util.tree_map(ns, pspecs)
    # FLOPs of useful work: beam hops × degree × dim MACs per query
    mf = 2.0 * E.query_batch * E.max_iters * E.max_degree * E.dim
    # HBM: per hop gather R×(d vector + adjacency row) + beam state churn
    hbm = (E.query_batch * E.max_iters
           * (E.max_degree * (E.dim * 4 + 4) + E.beam_width * 16)
           + E.query_batch * E.bucket_capacity * 8)
    return (step, (sds, q_sds), (state_sh, ns(P(qaxes, None))), (0,), mf,
            hbm)


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if arch == "catapultdb":
        fn, args, shardings, donate, mf, hbm = catapultdb_specs(mesh)
    else:
        cfg = get_config(arch)
        if shape in cfg.skip_shapes:
            return {"arch": arch, "shape": shape,
                    "mesh": "multi_pod" if multi_pod else "single_pod",
                    "status": "skipped",
                    "reason": "inapplicable shape (DESIGN.md "
                              "§Arch-applicability)"}
        fn, args, shardings, donate, mf, hbm = input_specs(cfg, shape, mesh)

    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*args)
        hlo = lowered.as_text()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        terms = rl.analyze(compiled, compiled.as_text(), mesh.size,
                           model_flops=mf, hbm_bytes=hbm)

    out = {
        "arch": arch, "shape": shape,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "chips": mesh.size,
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": terms.as_dict(),
    }
    arg = out["memory"]["argument_bytes"] or 0
    tmp = out["memory"]["temp_bytes"] or 0
    outb = out["memory"]["output_bytes"] or 0
    alias = out["memory"]["alias_bytes"] or 0
    peak = arg + tmp + outb - alias
    out["memory"]["peak_bytes_per_chip"] = peak
    out["memory"]["fits_16GiB"] = bool(peak <= HBM_PER_CHIP)
    return out


def all_cells():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape
    yield "catapultdb", "search"


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", default="benchmarks/dryrun_results")
    args = p.parse_args()

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for arch, shape in all_cells():
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                dest = os.path.join(args.out, tag + ".json")
                if os.path.exists(dest):
                    print(f"[dryrun] {tag}: cached")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", dest]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append(tag)
                    print(f"[dryrun] {tag}: FAILED\n{r.stdout[-2000:]}"
                          f"\n{r.stderr[-2000:]}")
                else:
                    print(r.stdout.strip().splitlines()[-1])
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    res = run_cell(args.arch, args.shape, args.multi_pod)
    line = (f"[dryrun] {res['arch']}×{res['shape']}×{res['mesh']}: "
            f"{res['status']}")
    if res["status"] == "ok":
        peak = res["memory"]["peak_bytes_per_chip"]
        line += (f" peak={peak / GiB:.2f}GiB/chip "
                 f"fits={res['memory']['fits_16GiB']} "
                 f"dominant={res['roofline']['dominant']} "
                 f"compile={res['compile_s']}s")
    print(line)
    if args.out and args.out.endswith(".json"):
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
    elif args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = (f"{args.arch}__{args.shape}__"
               f"{'mp' if args.multi_pod else 'sp'}")
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
