"""End-to-end training driver: mesh, sharded init, data, checkpoints,
straggler monitoring, restart/elastic resume.

CLI (runs on CPU with reduced configs; the same code lowers onto the
production mesh):

    PYTHONPATH=src python -m repro.launch.train \
        --arch deepseek-moe-16b --reduced --steps 50 \
        --ckpt-dir /tmp/ckpt --ckpt-every 20 [--resume]

Fault-tolerance drill covered by tests/test_train_loop.py: kill between
checkpoints, resume, verify the loss curve continues bit-identically
(deterministic pipeline + checkpointed step).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, get_reduced
from repro.compat import mesh_context
from repro.data.pipeline import Prefetcher, TokenPipeline
from repro.ft import checkpoint as ckpt
from repro.ft.elastic import choose_mesh_shape, make_mesh_from_plan
from repro.ft.straggler import StepMonitor
from repro.launch.mesh import batch_axes
from repro.models import model as M
from repro.models.steps import make_train_step
from repro.optim import adamw


def build_shardings(cfg, mesh):
    pspec = M.pspecs(cfg)
    to_shard = lambda spec: NamedSharding(mesh, spec)
    param_sh = jax.tree_util.tree_map(to_shard, pspec)
    dspec = adamw.zero1_pspecs(M.specs(cfg), pspec,
                               data_size=mesh.shape.get("data", 1))
    opt_leaf_sh = jax.tree_util.tree_map(to_shard, dspec)
    return param_sh, opt_leaf_sh


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: str | None = None, ckpt_every: int = 0,
          resume: bool = False, opt_cfg: adamw.AdamWConfig | None = None,
          mesh=None, log=print):
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=steps)
    if mesh is None:
        plan = choose_mesh_shape(len(jax.devices()))
        mesh = make_mesh_from_plan(plan)
    ba = batch_axes(mesh)

    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = ((cfg.n_frontend_tokens, cfg.frontend_dim),
                             np.float32)
    if cfg.family == "encdec":
        extras["frames"] = ((seq_len, cfg.frontend_dim), np.float32)
    pipe = TokenPipeline(cfg.vocab_size, seq_len, global_batch,
                         extras=extras)

    param_sh, opt_sh = build_shardings(cfg, mesh)
    batch_sh = {k: NamedSharding(mesh, P(ba)) for k in
                ["tokens"] + list(extras)}

    with mesh_context(mesh):
        start_step = 0
        if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            example = {
                "params": M.specs(cfg),
                "opt": adamw.AdamWState(
                    mu=jax.tree_util.tree_map(
                        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        M.specs(cfg)),
                    nu=jax.tree_util.tree_map(
                        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        M.specs(cfg)),
                    step=jax.ShapeDtypeStruct((), jnp.int32)),
            }
            shards = {"params": param_sh,
                      "opt": adamw.AdamWState(mu=opt_sh, nu=opt_sh,
                                              step=NamedSharding(mesh, P()))}
            state, start_step = ckpt.restore(ckpt_dir, example,
                                             shardings=shards)
            params, opt_state = state["params"], state["opt"]
            log(f"[train] resumed from step {start_step}")
        else:
            init_fn = jax.jit(partial(M.init, cfg),
                              out_shardings=param_sh)
            params = init_fn(jax.random.PRNGKey(0))
            opt_state = jax.jit(adamw.init,
                                out_shardings=adamw.AdamWState(
                                    mu=opt_sh, nu=opt_sh,
                                    step=NamedSharding(mesh, P())))(params)

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg),
            in_shardings=(param_sh,
                          adamw.AdamWState(mu=opt_sh, nu=opt_sh,
                                           step=NamedSharding(mesh, P())),
                          batch_sh),
            donate_argnums=(0, 1))

        checkpointer = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        monitor = StepMonitor()
        prefetch = Prefetcher(pipe.batch_at, start_step=start_step)
        losses = []
        try:
            for step in range(start_step, steps):
                batch = prefetch.next()
                batch = {k: jax.device_put(v, batch_sh[k])
                         for k, v in batch.items()}
                with monitor:
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                    loss = float(metrics["loss"])
                losses.append(loss)
                if step % 10 == 0 or step == steps - 1:
                    log(f"[train] step={step} loss={loss:.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"t={monitor.median:.3f}s")
                for a in monitor.actions:
                    log(f"[straggler] {a}")
                monitor.actions.clear()
                if (checkpointer and ckpt_every
                        and (step + 1) % ckpt_every == 0):
                    checkpointer.save_async(
                        {"params": params, "opt": opt_state}, step + 1)
        finally:
            prefetch.close()
            if checkpointer:
                checkpointer.wait()
        return params, opt_state, losses


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--global-batch", type=int, default=4)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args()
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    train(cfg, steps=args.steps, global_batch=args.global_batch,
          seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
          ckpt_every=args.ckpt_every, resume=args.resume)


if __name__ == "__main__":
    main()
