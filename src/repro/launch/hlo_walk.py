"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE
regardless of trip count (verified empirically — scan(length=10) and
scan(length=20) report identical FLOPs), which silently destroys any
roofline derived from a scan-over-layers model.  This walker parses the
optimized HLO text and:

  * splits it into computations,
  * resolves each `while` op's body/condition and its
    ``known_trip_count`` backend config (XLA annotates constant-trip
    loops after optimization),
  * attributes dot FLOPs (2 × prod(out dims) × prod(contracting dims),
    operand shapes resolved through the per-computation def table),
  * attributes collective output bytes per op kind,
  * walks from ENTRY multiplying nested loop trip counts through
    `while`, `fusion(calls=…)`, `call`, and conditional branches.

Dot + convolution ops carry ≥95 % of FLOPs in every assigned arch, so
parsed-dot FLOPs is a tight lower bound on true executed FLOPs; the
analytic model in roofline.py cross-checks it.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->.*\{")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+"
                    r"([\w\-]+)\((.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _shape_dims(shape_str):
    """first array shape in the string -> (dtype, [dims])"""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _shape_bytes(shape_str):
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    colls: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    # (callee, multiplier) — while bodies carry trip, others 1
    edges: list = dataclasses.field(default_factory=list)


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    shapes: dict[str, str] = {}
    entry = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw.strip())
        if cur is None:
            m = _HEADER_RE.match(raw)
            if m:
                cur = Computation(name=m.group(2))
                shapes = {}
                if m.group(1):
                    entry = cur.name
                # parameters: "%comp (p0: f32[2,3], p1: s32[]) -> ..."
                params = re.findall(r"([\w\.\-]+):\s*(\(?[\w\[\],\s]*\]?)",
                                    raw)
                for pname, pshape in params:
                    shapes[pname] = pshape
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_shape, op, rest = m.groups()
        shapes[name] = out_shape

        if op == "dot":
            # Current XLA prints operands WITH inline shapes —
            # "dot(f32[128,128]{1,0} %x, ...)" — so parse the lhs shape
            # straight from the operand text; older name-only text
            # ("dot(%x, %y)") falls back to the def table.
            lhs_txt = rest.split(")")[0].split(", ")[0]
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            _, out_dims = _shape_dims(out_shape)
            _, lhs_dims = _shape_dims(lhs_txt)
            if not lhs_dims:
                lhs_name = re.search(r"%?([\w\.\-]+)\s*$", lhs_txt)
                _, lhs_dims = _shape_dims(
                    shapes.get(lhs_name.group(1), "") if lhs_name else "")
            k = 1
            if cd and lhs_dims:
                for idx in cd.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
            flops = 2.0 * k
            for d in out_dims:
                flops *= d
            cur.dot_flops += flops
        elif op == "convolution":
            # rough: 2 * out_elems * (in_ch * kernel_elems) — parse window
            _, out_dims = _shape_dims(out_shape)
            n = 1
            for d in out_dims:
                n *= d
            kw = re.search(r"window=\{size=([\dx]+)", line)
            kelems = 1
            if kw:
                for d in kw.group(1).split("x"):
                    kelems *= int(d)
            cur.dot_flops += 2.0 * n * kelems
        elif any(op.startswith(c) for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if op.startswith(c))
            cur.colls[kind] += _shape_bytes(out_shape)

        if op == "while":
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            body = re.search(r"body=%?([\w\.\-]+)", line)
            trip = re.search(r'known_trip_count.*?"n":"(\d+)"', line)
            t = float(trip.group(1)) if trip else 1.0
            if body:
                cur.edges.append((body.group(1), t))
            if cond:
                cur.edges.append((cond.group(1), t + 1))
        else:
            for callee in re.findall(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                     line):
                cur.edges.append((callee, 1.0))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for b in bm.group(1).split(","):
                    cur.edges.append((b.strip().lstrip("%"), 1.0))
    return comps, entry


def walk(text: str) -> dict:
    """Returns {'dot_flops': float, 'collectives': {kind: bytes}} with
    while-trip multipliers applied (per device)."""
    comps, entry = parse_hlo(text)
    memo: dict[str, tuple[float, dict]] = {}

    def visit(name: str, stack=()) -> tuple[float, dict]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}
        c = comps[name]
        flops = c.dot_flops
        colls = dict(c.colls)
        for callee, mult in c.edges:
            f2, c2 = visit(callee, stack + (name,))
            flops += mult * f2
            for k, v in c2.items():
                colls[k] = colls.get(k, 0.0) + mult * v
        memo[name] = (flops, colls)
        return memo[name]

    flops, colls = visit(entry) if entry else (0.0, {})
    return {"dot_flops": flops, "collectives": colls}
