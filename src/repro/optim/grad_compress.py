"""Gradient compression hooks (distributed-optimization trick).

Two composable pieces:

* ``compress``/``decompress`` — cast gradients to bf16 (or int8 with
  per-tensor scale) between backward and optimizer.  Under the data-
  parallel pjit step the cross-replica gradient reduction is fused into
  the backward pass by XLA, so the *wire* format of that all-reduce
  follows the tensor dtype: running the backward in bf16 params/activations
  already moves bf16 over the ICI.  These hooks cover the explicit
  accumulate-then-reduce path (gradient accumulation microbatching),
  halving (bf16) or quartering (int8) the reduction bytes.
* ``error_feedback`` — residual accumulation so quantization error is
  carried to the next step instead of lost (1-bit-Adam-style).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads)


class Int8Grad(NamedTuple):
    q: jax.Array
    scale: jax.Array


def compress_int8(grads):
    def one(g):
        a = jnp.max(jnp.abs(g.astype(jnp.float32)))
        scale = jnp.maximum(a, 1e-12) / 127.0
        return Int8Grad(q=jnp.clip(jnp.round(g / scale), -127, 127
                                   ).astype(jnp.int8), scale=scale)
    return jax.tree_util.tree_map(one, grads)


def decompress(grads):
    def one(g):
        if isinstance(g, Int8Grad):
            return g.q.astype(jnp.float32) * g.scale
        return g.astype(jnp.float32)
    return jax.tree_util.tree_map(
        one, grads, is_leaf=lambda x: isinstance(x, Int8Grad))


class ErrorFeedback(NamedTuple):
    residual: Any


def ef_init(params):
    return ErrorFeedback(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress(grads, ef: ErrorFeedback, kind="int8"):
    """Add residual, compress, store the new residual."""
    corrected = jax.tree_util.tree_map(
        lambda g, r: g.astype(jnp.float32) + r, grads, ef.residual)
    comp = compress_int8(corrected) if kind == "int8" \
        else compress_bf16(corrected)
    recon = decompress(comp)
    new_res = jax.tree_util.tree_map(lambda c, r: c - r, corrected, recon)
    return comp, ErrorFeedback(residual=new_res)
