"""optim substrate."""
