"""AdamW with ZeRO-1-style sharded optimizer states + cosine schedule.

Functional (init/update) with f32 moments regardless of param dtype.
``zero1_pspecs`` derives optimizer-state partition specs from the param
specs: each moment tensor additionally shards its largest replicated
axis over the `data` mesh axis (optimizer-state memory / `data`), the
standard ZeRO-1 layout.  Under pjit the resharding collectives are
inserted by XLA at the param-update boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0
    # memory-reduced moments: bf16 halves optimizer HBM (arctic-480b needs
    # this to fit a single 256-chip pod; see EXPERIMENTS.md §Dry-run)
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros),
                      step=jnp.int32(0))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moment_dtype)

    def core(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    def upd(p, g, m, v):
        # Layer-stacked leaves update one layer per loop step so the f32
        # staging copies are 1/n_layers-sized.  fori_loop + in-place
        # dynamic updates (not lax.map): map's whole-stack xs lets XLA
        # hoist the f32 converts back out of the loop, recreating the
        # full-stack copies (observed on arctic-480b).
        if p.ndim >= 3 and p.shape[0] >= 8:
            # g rides in the carry (unmodified) so XLA cannot prove it
            # loop-invariant and hoist a whole-stack f32 convert of it.
            def body(i, carry):
                cp, cm, cv, cg = carry
                sl = lambda a: jax.lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False)
                p2, m2, v2 = core(sl(cp), sl(cg), sl(cm), sl(cv))
                up = lambda a, x: jax.lax.dynamic_update_index_in_dim(
                    a, x, i, 0)
                return up(cp, p2), up(cm, m2), up(cv, v2), cg

            out = jax.lax.fori_loop(0, p.shape[0], body, (p, m, v, g))
            return out[0], out[1], out[2]
        return core(p, g, m, v)

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(new_m, new_v, step), metrics


def zero1_pspecs(param_specs, param_pspecs, data_axis="data",
                 data_size: int = 1):
    """Optimizer-state pspecs: shard the largest replicated axis of each
    moment over the data axis (ZeRO-1)."""

    def one(sds, spec):
        if spec is None:
            spec = P()
        flat = {a for e in spec if e is not None
                for a in ((e,) if isinstance(e, str) else tuple(e))}
        if data_axis in flat:      # already FSDP-sharded over data
            return spec
        axes = list(spec) + [None] * (len(sds.shape) - len(spec))
        best, best_dim = -1, 0
        for i, (ax, dim) in enumerate(zip(axes, sds.shape)):
            if ax is None and dim % max(data_size, 1) == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0 and data_size > 1:
            axes[best] = data_axis
        return P(*axes)

    return jax.tree_util.tree_map(one, param_specs, param_pspecs)
